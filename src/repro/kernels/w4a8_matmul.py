"""Pallas TPU kernel: W4A8 GEMM — packed-FP4 weights x FP8-quantized
activations, decoded in VMEM.

This is the paper's deployment kernel, adapted from H100 FP8 tensor cores to
the TPU memory hierarchy (DESIGN.md §2):

  * weights live in HBM as packed E2M1 nibbles (2/byte) + per-(row, group)
    scales — the HBM read per weight is 4 bits, which is the whole point on
    a bandwidth-bound decode step;
  * each (BM, BN, BK=group) tile is decoded to bf16 *in VMEM*: nibble
    unpack + a closed-form E2M1 decode (4 VPU ops), then an MXU bf16 matmul
    with f32 accumulation in a VMEM scratch accumulator;
  * scales: the per-group multiply folds into the tile's partial sum. With
    M2 (pow-2 constrained) scales the multiplier is 2^-k built directly from
    the exponent bit pattern (integer VPU op — the TPU equivalent of the
    paper's "bit shift" cast) and one final per-row s_max multiply;
  * activations arrive already token-wise FP8-quantized (values on the E4M3
    grid times their scale, stored bf16) from the act_quant kernel.

Grid: (M/BM, N/BN, K/BK), K innermost; out tile (BM, BN) f32 accumulates
across the K steps and is written once (revisiting semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["w4a8_matmul_pallas", "decode_e2m1"]


def _pow2i(k):
    k = jnp.clip(k.astype(jnp.int32), -126, 127)
    bits = (k + 127).astype(jnp.uint32) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def decode_e2m1(code):
    """uint4 code (as wider int) -> f32 value. Closed form for E2M1
    {0, .5, 1, 1.5, 2, 3, 4, 6}: sub-normal (exp==0) value is 0.5*man."""
    code = code.astype(jnp.int32)
    sign = (code >> 3) & 1
    exp = (code >> 1) & 3
    man = code & 1
    frac = 1.0 + 0.5 * man.astype(jnp.float32)
    val = _pow2i(exp - 1) * frac
    val = jnp.where(exp == 0, 0.5 * man.astype(jnp.float32), val)
    return jnp.where(sign == 1, -val, val)


def decode_e3m0(code):
    """E3M0 bias 3: pure powers of two, exp field 1..7 -> 2^-2..2^4."""
    code = code.astype(jnp.int32)
    sign = (code >> 3) & 1
    exp = code & 7
    val = jnp.where(exp == 0, 0.0, _pow2i(exp - 3))
    return jnp.where(sign == 1, -val, val)


_DECODERS = {"fp4_e2m1": decode_e2m1, "fp4_e3m0": decode_e3m0}


def _unpack(codes):
    """(n, k/2) packed uint8 -> (n, k) uint8 nibbles (low nibble first)."""
    lo = codes & jnp.uint8(0x0F)
    hi = (codes >> 4) & jnp.uint8(0x0F)
    return jnp.stack([lo, hi], axis=-1).reshape(codes.shape[0], -1)


def _kernel(x_ref, codes_ref, scale_ref, o_ref, *, w_fmt, nsteps, m2, smax_ref=None):
    """One (BM, BN) tile accumulating over the K grid dimension.

    x_ref: (BM, BK) bf16 — FP8-grid activation values (x scale)
    codes_ref: (BN, BK/2) uint8; scale_ref: (BN, 1) f32 (or shifts when m2)
    o_ref: (BM, BN) f32 accumulator
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    decode = _DECODERS[w_fmt]
    w_q = decode(_unpack(codes_ref[...]))  # (BN, BK) f32 on-grid
    if m2:
        # pow-2 group scale: multiplier from exponent bits (the bit-shift)
        gscale = _pow2i(-scale_ref[...].astype(jnp.int32))  # (BN, 1)
    else:
        gscale = scale_ref[...]  # (BN, 1) f32
    w = (w_q * gscale).astype(jnp.bfloat16)
    x = x_ref[...].astype(jnp.bfloat16)
    part = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += part

    if m2:

        @pl.when(k_step == nsteps - 1)
        def _finalize():
            o_ref[...] = o_ref[...] * smax_ref[...].reshape(1, -1)


@functools.partial(
    jax.jit,
    static_argnames=("w_fmt", "group_size", "bm", "bn", "interpret"),
)
def w4a8_matmul_pallas(
    x_q,
    codes,
    scale,
    s_max=None,
    shifts=None,
    w_fmt: str = "fp4_e2m1",
    group_size: int = 256,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
):
    """y[m, n] = sum_k x_q[m, k] * dequant(codes, scale)[n, k].

    x_q: (M, K) bf16/f32 — already FP8-quantized activation values x scale.
    codes: (N, K/2) uint8; scale: (N, G) f32; optional M2 (s_max, shifts).
    Returns (M, N) f32. Shapes must tile: M % bm == 0 is relaxed by clamping
    bm to a divisor; K % group_size == 0 required (FGQ invariant).
    """
    m, k = x_q.shape
    n = codes.shape[0]
    bk = group_size
    assert k % bk == 0, (k, bk)
    bm = min(bm, m)
    while m % bm:
        bm -= 1
    bn = min(bn, n)
    while n % bn:
        bn -= 1
    nsteps = k // bk
    m2 = shifts is not None

    scale_in = shifts.astype(jnp.int32) if m2 else scale
    args = [x_q.astype(jnp.bfloat16), codes, scale_in]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
        pl.BlockSpec((bn, bk // 2), lambda i, j, s: (j, s)),
        pl.BlockSpec((bn, 1), lambda i, j, s: (j, s)),
    ]
    if m2:
        args.append(s_max.reshape(n, 1))
        in_specs.append(pl.BlockSpec((bn, 1), lambda i, j, s: (j, 0)))

    kernel = functools.partial(_kernel, w_fmt=w_fmt, nsteps=nsteps, m2=m2)
    if m2:
        def kernel(x_ref, c_ref, s_ref, sm_ref, o_ref):  # noqa: F811
            _kernel(x_ref, c_ref, s_ref, o_ref, w_fmt=w_fmt, nsteps=nsteps,
                    m2=True, smax_ref=sm_ref)

    out = pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nsteps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*args)
    return out
