"""Whole-model PTQ driver.

Three entry points:
  * ``pack_linear(w, policy)``       — one weight -> PackedLinear (RTN or a
                                       pre-computed GPTQ QuantizedTensor).
  * ``quantize_tree(params, defs, policy)`` — walk a model's param tree,
                                       replace every quantizable leaf with
                                       its W4A8 deployment form. RTN path
                                       (no calibration); used for serving
                                       dry-runs and as the GPTQ fallback.
  * ``gptq_quantize_lm(params, cfg, calib, policy)`` — the paper's pipeline:
                                       layer-by-layer GPTQ over a calibration
                                       stream with error propagation through
                                       the quantized prefix, capturing the
                                       four module inputs of Fig. 1
                                       (q_proj, out_proj, fc1, fc2)
                                       [+ gate for gated MLPs], then LoRC.

Quantizability of a leaf is decided from its ParamDef: a >=2-D 'normal'-init
matrix whose trailing (out, in) dims are both >= 64, not an embedding /
vocab-tied / conv / router weight.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import PackedLinear
from repro.models.params import ParamDef

from .formats import FORMATS, FloatFormat, fp_encode, pack_nibbles
from .gptq import gptq_quantize, hessian_init, hessian_update
from .lorc import lorc_compensate
from .policy import QuantPolicy
from .quantize import fake_quantize_weight, quantize_weight
from .scales import constrain_scales_m2

__all__ = [
    "is_quantizable",
    "effective_group",
    "pack_linear",
    "packed_def",
    "quantize_tree",
    "quantized_shape_tree",
    "gptq_quantize_lm",
]


def is_quantizable(d: ParamDef, path: str = "") -> bool:
    if not isinstance(d, ParamDef):
        return False
    if d.init != "normal" or len(d.shape) < 2:
        return False
    if "vocab" in d.axes or "conv" in d.axes:
        return False
    if "router" in path or "pos_embed" in path:
        return False
    out_f, in_f = d.shape[-2], d.shape[-1]
    return out_f >= 64 and in_f >= 64 and in_f % 2 == 0


def effective_group(in_features: int, group: int) -> int:
    """Largest divisor of in_features that is <= group.
    The paper adjusts group to the hidden size (e.g. 320 for LLaMA-3b)."""
    g = min(group, in_features)
    while g > 1 and in_features % g:
        g -= 1
    return max(g, 1)


def _pack_fp(qvalues, scale, policy: QuantPolicy, group_size: int, lorc=None):
    fmt = FORMATS[policy.w_fmt]
    codes = pack_nibbles(fp_encode(qvalues, fmt))
    s_max = shifts = None
    if policy.scale_mode == "m2":
        m2 = constrain_scales_m2(scale)
        s_max, shifts = m2.s_max, m2.shifts.astype(jnp.int8)
    return PackedLinear(
        codes=codes,
        scale=scale.astype(jnp.float32),
        s_max=s_max,
        shifts=shifts,
        lorc_a=None if lorc is None else lorc.a.astype(jnp.bfloat16),
        lorc_b=None if lorc is None else lorc.b.astype(jnp.bfloat16),
        w_fmt=policy.w_fmt,
        a_fmt=policy.a_fmt,
        group_size=group_size,
    )


def pack_linear(w, policy: QuantPolicy, qt=None, with_lorc: Optional[bool] = None):
    """Quantize + pack one (out, in) weight. ``qt`` may carry a GPTQ result.

    FP4 weights -> nibble-packed PackedLinear. Other weight formats fall back
    to fake-quantized dense bf16 (the paper's deployment target is FP4)."""
    w = jnp.asarray(w)
    gs = effective_group(w.shape[-1], policy.group_size)
    if qt is None:
        from .scales import apply_scale_constraint

        qt0 = quantize_weight(w.astype(jnp.float32), policy.w_fmt, gs)
        scale = apply_scale_constraint(qt0.scale, policy.scale_mode)
        qt = quantize_weight(w.astype(jnp.float32), policy.w_fmt, gs, scale=scale)

    use_lorc = policy.lorc_rank > 0 if with_lorc is None else with_lorc
    lorc = None
    if use_lorc:
        w_hat = qt.dequantize()
        lorc = lorc_compensate(w.astype(jnp.float32), w_hat, policy.lorc_rank,
                               quantize_factors=policy.lorc_fmt)

    if not str(policy.w_fmt).startswith("fp4"):
        # dense fallback: fake-quantized weights (sim path)
        return None
    return _pack_fp(qt.values, qt.scale, policy, qt.group_size, lorc)


def _pack_batched(w, policy: QuantPolicy):
    """Quantize + pack a (..., out, in) stacked weight by vmapping RTN."""
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])

    def one(wi):
        pl = pack_linear(wi, policy)
        return pl

    packed = [one(flat[i]) for i in range(flat.shape[0])]
    # restack fields
    def stack(field):
        vals = [getattr(p, field) for p in packed]
        if vals[0] is None:
            return None
        return jnp.stack(vals).reshape(lead + vals[0].shape)

    p0 = packed[0]
    return PackedLinear(
        codes=stack("codes"), scale=stack("scale"), s_max=stack("s_max"),
        shifts=stack("shifts"), lorc_a=stack("lorc_a"), lorc_b=stack("lorc_b"),
        w_fmt=p0.w_fmt, a_fmt=p0.a_fmt, group_size=p0.group_size,
    )


def packed_def(d: ParamDef, policy: QuantPolicy):
    """ShapeDtypeStruct PackedLinear matching what quantize_tree produces —
    the dry-run stand-in for a quantized checkpoint (no allocation)."""
    lead = d.shape[:-2]
    out_f, in_f = d.shape[-2], d.shape[-1]
    gs = effective_group(in_f, policy.group_size)
    ng = in_f // gs
    sds = jax.ShapeDtypeStruct
    m2 = policy.scale_mode == "m2"
    r = policy.lorc_rank
    return PackedLinear(
        codes=sds(lead + (out_f, in_f // 2), jnp.uint8),
        scale=sds(lead + (out_f, ng), jnp.float32),
        s_max=sds(lead + (out_f, 1), jnp.float32) if m2 else None,
        shifts=sds(lead + (out_f, ng), jnp.int8) if m2 else None,
        lorc_a=sds(lead + (out_f, r), jnp.bfloat16) if r else None,
        lorc_b=sds(lead + (r, in_f), jnp.bfloat16) if r else None,
        w_fmt=policy.w_fmt, a_fmt=policy.a_fmt, group_size=gs,
    )


def _map_with_defs(fn, params, defs):
    """tree.map over (params, defs) with path strings; defs leaves=ParamDef."""
    is_def = lambda x: isinstance(x, ParamDef)
    flat_defs, treedef = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)
    flat_params = treedef.flatten_up_to(params)
    out = []
    for (path, d), p in zip(flat_defs, flat_params):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append(fn(pstr, d, p))
    return jax.tree.unflatten(treedef, out)


def quantize_tree(params, defs, policy: QuantPolicy):
    """RTN-quantize every quantizable leaf -> serving param tree.

    Non-FP4 weight policies keep dense (fake-quantized) weights; FP4 leaves
    become PackedLinear."""

    def visit(path, d, p):
        if not is_quantizable(d, path):
            return p
        if str(policy.w_fmt).startswith("fp4"):
            if len(d.shape) == 2:
                return pack_linear(p, policy)
            return _pack_batched(p, policy)
        gs = effective_group(d.shape[-1], policy.group_size)
        if len(d.shape) == 2:
            return fake_quantize_weight(p.astype(jnp.float32), policy.w_fmt, gs).astype(p.dtype)
        flat = p.reshape((-1,) + p.shape[-2:]).astype(jnp.float32)
        q = jnp.stack([fake_quantize_weight(flat[i], policy.w_fmt, gs) for i in range(flat.shape[0])])
        return q.reshape(p.shape).astype(p.dtype)

    return _map_with_defs(visit, params, defs)


def quantized_shape_tree(defs, policy: QuantPolicy):
    """ShapeDtypeStruct tree of the serving checkpoint (dry-run input)."""

    def visit(path, d, _p):
        if is_quantizable(d, path) and str(policy.w_fmt).startswith("fp4"):
            return packed_def(d, policy)
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype))

    shapes = jax.tree.map(lambda d: d, defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return _map_with_defs(visit, shapes, defs)


# ---------------------------------------------------------------------------
# GPTQ pipeline for the dense (gqa + mlp) LM family — the paper's procedure
# ---------------------------------------------------------------------------
def gptq_quantize_lm(params, cfg, calib_batches: List, policy: QuantPolicy,
                     progress: bool = False):
    """Layer-by-layer GPTQ with error propagation (paper §3 / Appendix A).

    Works on the dense transformer family (cfg.attn_kind == 'gqa', mlp ffn,
    no moe/ssm). Captures the four Fig.-1 module inputs per layer:
      attn.q_proj (shared for q/k/v), attn.out_proj, fc1 (+gate), fc2.
    Returns a new params tree with quantized (packed or dense-fake) weights.
    """
    from repro.models import transformer as _tf
    from repro.models.attention import attention
    from repro.models.layers import linear as _linear
    from repro.models.layers import activation as _act
    from repro.models.layers import mlp as _mlp
    from repro.models.layers import norm as _norm

    assert cfg.attn_kind == "gqa" and cfg.moe is None and cfg.ssm is None
    seg = _tf.segments_for(cfg)[0]
    nk = cfg.norm_kind

    # embed calibration tokens once
    xs = []
    for b in calib_batches:
        x = _tf._embed_tokens(params, cfg, b["tokens"])
        if cfg.pos_embedding == "learned":
            x = x + params["pos_embed"][: x.shape[1]][None].astype(x.dtype)
        xs.append(x)

    stack = params["segments"][0]
    n_layers = jax.tree.leaves(stack)[0].shape[0]
    new_stack = jax.tree.map(lambda a: np.asarray(a).copy(), stack)

    def quantize_one(w, hstate, name):
        gs = effective_group(w.shape[-1], policy.group_size)
        if policy.method == "gptq":
            _, qt = gptq_quantize(
                w.astype(jnp.float32), hstate.h, policy.w_fmt, group_size=gs,
                scale_mode=policy.scale_mode, damp=policy.damp,
                block=min(128, gs),
            )
        else:
            from .scales import apply_scale_constraint

            qt0 = quantize_weight(w.astype(jnp.float32), policy.w_fmt, gs)
            s = apply_scale_constraint(qt0.scale, policy.scale_mode)
            qt = quantize_weight(w.astype(jnp.float32), policy.w_fmt, gs, scale=s)
        w_hat = qt.dequantize()
        if policy.lorc_rank:
            fac = lorc_compensate(w.astype(jnp.float32), w_hat, policy.lorc_rank,
                                  quantize_factors=policy.lorc_fmt)
            w_hat = w_hat + fac.a @ fac.b
        return w_hat.astype(w.dtype)

    for li in range(n_layers):
        p_layer = jax.tree.map(lambda a: jnp.asarray(a[li]), new_stack)
        pm, pf = p_layer["mixer"], p_layer["ffn"]

        # ---- capture module inputs over the calibration stream ------------
        caps = {k: None for k in ("qkv", "out", "fc1", "fc2")}

        def upd(key, val):
            st = caps[key] if caps[key] is not None else hessian_init(val.shape[-1])
            caps[key] = hessian_update(st, val)

        for x in xs:
            b, s, _ = x.shape
            pos = jnp.arange(s)
            h_ln = _norm(pm["ln"], x, nk, cfg.norm_eps)
            upd("qkv", h_ln)
            # replicate attention internals to capture out_proj input
            hd, h_q, kv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
            from repro.models.attention import block_mask, _repeat_kv, _sdpa_full
            from repro.models.layers import apply_rope

            q = _linear(pm["attn"]["wq"], h_ln, pm["attn"].get("bq")).reshape(b, s, h_q, hd)
            k = _linear(pm["attn"]["wk"], h_ln).reshape(b, s, kv, hd)
            v = _linear(pm["attn"]["wv"], h_ln, pm["attn"].get("bv")).reshape(b, s, kv, hd)
            if cfg.pos_embedding == "rope":
                q, k = apply_rope(q, pos, cfg.rope_theta), apply_rope(k, pos, cfg.rope_theta)
            g = h_q // kv
            o = _sdpa_full(q, _repeat_kv(k, g), _repeat_kv(v, g),
                           block_mask(s, s, 0, 0, cfg.causal, 0)).reshape(b, s, h_q * hd)
            upd("out", o)
            attn_out = _linear(pm["attn"]["wo"], o, pm["attn"].get("bo"))
            x_mid = x + attn_out
            f_ln = _norm(pf["ln"], x_mid, nk, cfg.norm_eps)
            upd("fc1", f_ln)
            up = _linear(pf["mlp"]["up"], f_ln, pf["mlp"].get("up_b"))
            if "gate" in pf["mlp"]:
                hmid = _act(_linear(pf["mlp"]["gate"], f_ln), cfg.act_kind) * up
            else:
                hmid = _act(up, cfg.act_kind)
            upd("fc2", hmid)

        # ---- quantize this layer's weights --------------------------------
        wmap = [
            (("mixer", "attn", "wq"), "qkv"), (("mixer", "attn", "wk"), "qkv"),
            (("mixer", "attn", "wv"), "qkv"), (("mixer", "attn", "wo"), "out"),
            (("ffn", "mlp", "up"), "fc1"), (("ffn", "mlp", "down"), "fc2"),
        ]
        if "gate" in p_layer["ffn"]["mlp"]:
            wmap.append((("ffn", "mlp", "gate"), "fc1"))
        for keys, cap in wmap:
            node = new_stack
            for k in keys[:-1]:
                node = node[k]
            w_old = jnp.asarray(node[keys[-1]][li])
            w_new = quantize_one(w_old, caps[cap], "/".join(keys))
            node[keys[-1]][li] = np.asarray(w_new)

        # ---- propagate quantized layer outputs ----------------------------
        p_q = jax.tree.map(lambda a: jnp.asarray(a[li]), new_stack)
        xs_new = []
        for x in xs:
            b, s, _ = x.shape
            pos = jnp.arange(s)
            y, _, _ = _tf.block_apply(p_q, x, cfg, seg, pos)
            xs_new.append(y)
        xs = xs_new
        if progress:
            print(f"  gptq layer {li + 1}/{n_layers} done")

    out = dict(params)
    out["segments"] = [jax.tree.map(jnp.asarray, new_stack)]
    return out
