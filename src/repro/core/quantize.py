"""Quantizers: fine-grained (group-wise) weight quantization and token-wise
activation quantization, for both integer and floating-point grids.

Weight convention follows GPTQ / ZeroQuant-V2 FGQ: a weight matrix is
``(out_features, in_features)``; groups of ``group_size`` consecutive input
channels share a scale *per output row*, so scales have shape
``(out_features, in_features // group_size)``. The paper uses group 256.

Activation convention is token-wise (per row of the flattened ``(tokens,
features)`` activation), matching the paper's latency-friendly scheme.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from .formats import FloatFormat, IntFormat, get_format, quantize_to_grid

__all__ = [
    "QuantizedTensor",
    "compute_scales",
    "quantize_weight",
    "dequantize_weight",
    "fake_quantize_weight",
    "quantize_act_tokenwise",
    "fake_quantize_act",
]

_EPS = 1e-12


class QuantizedTensor(NamedTuple):
    """Quant-sim container: values on the target grid (pre-scale), + scales.

    ``values`` are the *normalized* on-grid numbers q (so w_hat = q * scale,
    broadcast per group). ``zero_point`` is None for symmetric schemes.
    """

    values: jnp.ndarray  # same shape as the source tensor, f32 on-grid
    scale: jnp.ndarray  # (out, n_groups) for weights; (tokens, 1) for acts
    zero_point: Optional[jnp.ndarray]
    group_size: int
    fmt_name: str

    def dequantize(self) -> jnp.ndarray:
        return dequantize_weight(self)


def _grid_max(fmt) -> float:
    if isinstance(fmt, FloatFormat):
        return fmt.max_value
    return float(fmt.qmax)


def _round_to_fmt(x, fmt):
    """Round pre-scaled x onto the format grid."""
    if isinstance(fmt, FloatFormat):
        return quantize_to_grid(x, fmt)
    # integer: RNE then clamp
    return jnp.clip(jnp.round(x), fmt.qmin, fmt.qmax)


def compute_scales(w_groups, fmt, symmetric: bool = True):
    """Scales (and zero points) for grouped weights.

    w_groups: (..., group_size) — the last axis is the group.
    Returns (scale, zero_point) broadcastable against w_groups.
    """
    if symmetric or isinstance(fmt, FloatFormat):
        absmax = jnp.max(jnp.abs(w_groups), axis=-1, keepdims=True)
        # multiply by the f32 reciprocal instead of dividing: bit-identical
        # between eager, jit and pallas-interpret execution (divisions by a
        # constant are reciprocal-rewritten inconsistently across backends)
        scale = absmax * jnp.float32(1.0 / _grid_max(fmt))
        scale = jnp.maximum(scale, _EPS)
        return scale, None
    # asymmetric integer
    wmax = jnp.max(w_groups, axis=-1, keepdims=True)
    wmin = jnp.min(w_groups, axis=-1, keepdims=True)
    scale = (wmax - wmin) / fmt.levels
    scale = jnp.maximum(scale, _EPS)
    zero = jnp.round(-wmin / scale) + fmt.qmin
    return scale, zero


def quantize_weight(
    w,
    fmt_name: str,
    group_size: int = 256,
    scale: Optional[jnp.ndarray] = None,
) -> QuantizedTensor:
    """FGQ group-wise quantization of a (out, in) weight matrix.

    If ``scale`` (out, n_groups) is provided it is used as-is (this is how
    the pow-2 constrained scales from core.scales are injected).
    """
    fmt = get_format(fmt_name)
    out_f, in_f = w.shape
    if group_size <= 0 or group_size > in_f:
        group_size = in_f
    assert in_f % group_size == 0, (in_f, group_size)
    n_groups = in_f // group_size
    wg = w.reshape(out_f, n_groups, group_size).astype(jnp.float32)

    symmetric = not (isinstance(fmt, IntFormat) and not fmt.symmetric)
    if scale is None:
        s, z = compute_scales(wg, fmt, symmetric=symmetric)
    else:
        s = scale.reshape(out_f, n_groups, 1).astype(jnp.float32)
        s = jnp.maximum(s, _EPS)
        z = None
        if not symmetric:
            _, z = compute_scales(wg, fmt, symmetric=False)

    if symmetric:
        q = _round_to_fmt(wg / s, fmt)
    else:
        q = jnp.clip(jnp.round(wg / s) + z, fmt.qmin, fmt.qmax)

    return QuantizedTensor(
        values=q.reshape(out_f, in_f),
        scale=s.reshape(out_f, n_groups),
        zero_point=None if z is None else z.reshape(out_f, n_groups),
        group_size=group_size,
        fmt_name=fmt_name,
    )


def dequantize_weight(qt: QuantizedTensor) -> jnp.ndarray:
    out_f, in_f = qt.values.shape
    n_groups = in_f // qt.group_size
    q = qt.values.reshape(out_f, n_groups, qt.group_size)
    s = qt.scale.reshape(out_f, n_groups, 1)
    if qt.zero_point is not None:
        z = qt.zero_point.reshape(out_f, n_groups, 1)
        q = q - z
    return (q * s).reshape(out_f, in_f)


def fake_quantize_weight(w, fmt_name: str, group_size: int = 256, scale=None):
    """quantize->dequantize in one call (the PTQ simulator hot path)."""
    if get_format(fmt_name) is None:
        return w
    return dequantize_weight(quantize_weight(w, fmt_name, group_size, scale))


# ---------------------------------------------------------------------------
# Activations — token-wise
# ---------------------------------------------------------------------------
def quantize_act_tokenwise(x, fmt_name: str):
    """Token-wise quantization of activations.

    x: (..., features). Each token (all leading dims) gets one scale from
    its feature-axis absmax. Returns (q_values_on_grid, scale) with
    x_hat = q * scale. Symmetric for both INT and FP (the paper's scheme).
    """
    fmt = get_format(fmt_name)
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax * jnp.float32(1.0 / _grid_max(fmt)), _EPS)
    q = _round_to_fmt(x / scale, fmt)
    return q, scale


def fake_quantize_act(x, fmt_name: str):
    """Token-wise quantize->dequantize; identity for fmt 'none'/'fp16-ish'."""
    if get_format(fmt_name) is None:
        return x
    orig = x.dtype
    q, scale = quantize_act_tokenwise(x, fmt_name)
    return (q * scale).astype(orig)
