"""QuantPolicy — one object describing a full PTQ configuration.

This is the user-facing axis of the paper's experiment matrix:
  weight format x activation format x group size x LoRC rank x scale mode
e.g. the paper's headline scheme is
  QuantPolicy(w_fmt='fp4_e2m1', a_fmt='fp8_e4m3', group_size=256,
              lorc_rank=8, scale_mode='m2', method='gptq')
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["QuantPolicy", "PRESETS"]


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    # weight quantization
    w_fmt: Optional[str] = None  # None => keep fp16/bf16 weights
    group_size: int = 256
    method: str = "rtn"  # 'rtn' | 'gptq'
    scale_mode: str = "none"  # 'none' | 'm1' | 'm2'
    # activation quantization (token-wise)
    a_fmt: Optional[str] = None  # None => full precision activations
    # LoRC
    lorc_rank: int = 0
    lorc_fmt: Optional[str] = None  # quantize LoRC factors (e.g. 'int8')
    # GPTQ details
    damp: float = 0.01
    calib_tokens: int = 128 * 2048  # paper: 128 C4 sentences x 2048 tokens

    @property
    def quantizes_weights(self) -> bool:
        return self.w_fmt is not None

    @property
    def quantizes_acts(self) -> bool:
        return self.a_fmt is not None

    def describe(self) -> str:
        w = self.w_fmt or "fp16"
        a = self.a_fmt or "fp16"
        bits = {"fp4_e2m1": "W4", "fp4_e3m0": "W4", "int4": "W4", "int4_asym": "W4",
                "fp8_e4m3": "W8", "fp8_e5m2": "W8", "int8": "W8", "int8_asym": "W8"}
        abits = {"fp8_e4m3": "A8", "fp8_e5m2": "A8", "int8": "A8", "int8_asym": "A8"}
        tag = f"{bits.get(self.w_fmt, 'W16')}{abits.get(self.a_fmt, 'A16')}"
        extra = []
        if self.method == "gptq":
            extra.append("gptq")
        if self.lorc_rank:
            extra.append(f"lorc{self.lorc_rank}")
        if self.scale_mode != "none":
            extra.append(self.scale_mode)
        return f"{tag}[{w}/{a}]" + ("+" + "+".join(extra) if extra else "")


# Named presets mirroring the paper's table rows.
PRESETS = {
    "w16a16": QuantPolicy(),
    # W8A8 rows of Table 2
    "w8a8_int_int": QuantPolicy(w_fmt="int8", a_fmt="int8", method="gptq"),
    "w8a8_int_fp": QuantPolicy(w_fmt="int8", a_fmt="fp8_e4m3", method="gptq"),
    "w8a8_fp_fp": QuantPolicy(w_fmt="fp8_e4m3", a_fmt="fp8_e4m3", method="gptq"),
    # W4A8 rows of Table 2
    "w4a8_int_int": QuantPolicy(w_fmt="int4", a_fmt="int8", method="gptq"),
    "w4a8_int_fp": QuantPolicy(w_fmt="int4", a_fmt="fp8_e4m3", method="gptq"),
    "w4a8_fp_fp": QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq"),
    # + LoRC rows
    "w4a8_int_int_lorc": QuantPolicy(w_fmt="int4", a_fmt="int8", method="gptq", lorc_rank=8),
    "w4a8_int_fp_lorc": QuantPolicy(w_fmt="int4", a_fmt="fp8_e4m3", method="gptq", lorc_rank=8),
    "w4a8_fp_fp_lorc": QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq", lorc_rank=8),
    # Table 3: scale constraints on the FP-FP W4A8 scheme
    "w4a8_fp_fp_m1": QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq", scale_mode="m1"),
    "w4a8_fp_fp_m2": QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq", scale_mode="m2"),
    "w4a8_fp_fp_m1_lorc": QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq", scale_mode="m1", lorc_rank=8),
    "w4a8_fp_fp_m2_lorc": QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq", scale_mode="m2", lorc_rank=8),
    # Table A.1: E3M0 weight alternative
    "w4a8_e3m0_fp": QuantPolicy(w_fmt="fp4_e3m0", a_fmt="fp8_e4m3", method="gptq"),
    # deployment default (paper's recommendation)
    "deploy_w4a8": QuantPolicy(
        w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq", scale_mode="m2", lorc_rank=8
    ),
}
