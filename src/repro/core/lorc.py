"""LoRC — Low Rank Compensation (ZeroQuant-V2, used by ZeroQuant-FP).

Given W and its quantized estimate W_q, the error E = W - W_q is SVD'd and
approximated by rank-r factors:

    E ~= U_r diag(s_r) V_r^T  =  (U_r sqrt(s_r)) (sqrt(s_r) V_r^T) = A B

At inference the effective weight is W_q + A B, applied as a fused low-rank
side path:  y = W_q x + A (B x)  — two skinny GEMMs, negligible FLOPs/bytes
for r << min(out, in). The paper uses r=8 (LLaMA) / 16..56 (OPT) and notes
r>=8 is enough.

Optionally the factors themselves are quantized to 8-bit (the deployment
variant ZeroQuant-V2 describes); exposed via ``quantize_factors``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from .quantize import fake_quantize_weight

__all__ = ["LorcFactors", "lorc_compensate", "lorc_apply"]


class LorcFactors(NamedTuple):
    a: jnp.ndarray  # (out, r)
    b: jnp.ndarray  # (r, in)


def lorc_compensate(
    w,
    w_q,
    rank: int,
    quantize_factors: Optional[str] = None,
    factor_group: int = 0,
) -> LorcFactors:
    """Rank-``rank`` SVD compensation of the quantization error W - W_q."""
    err = (w - w_q).astype(jnp.float32)
    u, s, vt = jnp.linalg.svd(err, full_matrices=False)
    r = min(rank, s.shape[0])
    sq = jnp.sqrt(s[:r])
    a = u[:, :r] * sq[None, :]
    b = sq[:, None] * vt[:r, :]
    if quantize_factors:
        a = fake_quantize_weight(a, quantize_factors, group_size=factor_group or a.shape[1])
        b = fake_quantize_weight(b, quantize_factors, group_size=factor_group or b.shape[1])
    return LorcFactors(a=a, b=b)


def lorc_apply(w_q, factors: Optional[LorcFactors]):
    """Effective dense weight W_q + A B (simulation path)."""
    if factors is None:
        return w_q
    return w_q + factors.a @ factors.b
