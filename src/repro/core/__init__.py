"""repro.core — the paper's contribution: FP-format post-training quantization.

Public surface:
  formats   — ExMy grids (E4M3/E5M2/E2M1/E3M0), INT grids, encode/decode
  quantize  — FGQ group-wise weight quant, token-wise activation quant
  gptq      — Hessian-guided one-shot weight rounding with error feedback
  lorc      — low-rank compensation of quantization error
  scales    — power-of-2 scale constraints (M1/M2) for FP4->FP8 casting
  policy    — QuantPolicy presets mirroring the paper's experiment matrix
  ptq       — whole-model PTQ driver (calibrate -> GPTQ -> LoRC -> pack)
"""
from .formats import (
    FORMATS,
    FloatFormat,
    IntFormat,
    fp_decode,
    fp_encode,
    get_format,
    pack_nibbles,
    quantize_to_grid,
    unpack_nibbles,
    value_grid,
)
from .gptq import HessianState, gptq_quantize, hessian_init, hessian_update
from .lorc import LorcFactors, lorc_apply, lorc_compensate
from .policy import PRESETS, QuantPolicy
from .quantize import (
    QuantizedTensor,
    dequantize_weight,
    fake_quantize_act,
    fake_quantize_weight,
    quantize_act_tokenwise,
    quantize_weight,
)
from .scales import M2Scales, apply_scale_constraint, constrain_scales_m1, constrain_scales_m2

__all__ = [
    "FORMATS", "FloatFormat", "IntFormat", "fp_decode", "fp_encode",
    "get_format", "pack_nibbles", "quantize_to_grid", "unpack_nibbles",
    "value_grid", "HessianState", "gptq_quantize", "hessian_init",
    "hessian_update", "LorcFactors", "lorc_apply", "lorc_compensate",
    "PRESETS", "QuantPolicy", "QuantizedTensor", "dequantize_weight",
    "fake_quantize_act", "fake_quantize_weight", "quantize_act_tokenwise",
    "quantize_weight", "M2Scales", "apply_scale_constraint",
    "constrain_scales_m1", "constrain_scales_m2",
]
