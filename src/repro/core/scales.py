"""Power-of-2 scale constraints (paper §3, "Casting the FP4 to FP8").

The W4A8 deployment problem: weights are FP4 (E2M1) with per-group FP scales,
activations are FP8 (E4M3). On H100 the W must be cast to FP8 before the
GEMM; on TPU our Pallas kernel decodes FP4->bf16 in VMEM. Either way an
arbitrary real scale forces a multiply (and a scale-table gather) per group
in the hot loop. Constraining scales to powers of two turns the scale apply
into an exponent add (integer add on the bit pattern) — a bit shift.

Two methods from the paper:

  (M1) snap every scale to the nearest-above power of two:
         S_hat = 2^ceil(log2 S)
  (M2) per *compute group* (here: the groups of one output row, or several
       rows — configurable), keep one full-precision S_max = max_i S_i and
       snap only the ratios:
         k_i   = ceil(log2(S_max / S_i))        (k_i >= 0, integer)
         S_hat_i = S_max * 2^-k_i
       Then dequant multiplies by S_max once (outside the loop) and applies
       2^-k_i as an exponent subtraction per group. M2 approximates much
       better than M1 (Table 3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .formats import pow2i

__all__ = ["constrain_scales_m1", "constrain_scales_m2", "M2Scales", "apply_scale_constraint"]


class M2Scales(NamedTuple):
    scales: jnp.ndarray  # constrained real scales S_hat (same shape as input)
    s_max: jnp.ndarray  # per compute group full-precision scale
    shifts: jnp.ndarray  # integer k_i >= 0 with S_hat_i = s_max * 2^-k_i


def constrain_scales_m1(scales):
    """M1: S_hat = 2^ceil(log2 S). Exact powers of two are kept."""
    scales = scales.astype(jnp.float32)
    n = jnp.ceil(jnp.log2(jnp.maximum(scales, 1e-30)))
    return pow2i(n.astype(jnp.int32))


def constrain_scales_m2(scales, group_axis: int = -1, max_shift: int = 31,
                        rounding: str = "ceil") -> M2Scales:
    """M2: per compute group along ``group_axis``.

    ``scales`` is typically (out_rows, n_groups); the compute group (the set
    sharing one S_max) defaults to the row (axis -1), matching "a (multiple)
    row(s) of a matrix" in the paper. ``max_shift`` bounds k for fixed-width
    exponent arithmetic in the kernel (int8 shift table -> 31 is generous).

    ``rounding`` picks which side of the raw scale the snapped ratio lands:
      * 'ceil'  (paper): k = ceil(log2 ratio), S_hat_i <= S_i — tighter grid
        use, saturates the group max (weights absorb this via GPTQ/LoRC).
      * 'floor': k = floor(log2 ratio), S_hat_i in [S_i, 2 S_i) — never
        saturates. For FP target grids the relative step is scale-invariant,
        so this costs (at most) one top binade; it is what content-dependent
        activation stores (the paged FP8 KV cache) use.
    """
    scales = scales.astype(jnp.float32)
    s_max = jnp.max(scales, axis=group_axis, keepdims=True)
    ratio = jnp.maximum(s_max / jnp.maximum(scales, 1e-30), 1.0)
    rnd = {"ceil": jnp.ceil, "floor": jnp.floor}[rounding]
    k = rnd(jnp.log2(ratio))
    k = jnp.clip(k, 0, max_shift)
    constrained = s_max * pow2i(-k.astype(jnp.int32))
    return M2Scales(scales=constrained, s_max=s_max, shifts=k.astype(jnp.int32))


def apply_scale_constraint(scales, mode: str, group_axis: int = -1):
    """Dispatch: mode in {'none', 'm1', 'm2'} -> constrained real scales."""
    if mode in (None, "none"):
        return scales
    if mode == "m1":
        return constrain_scales_m1(scales)
    if mode == "m2":
        return constrain_scales_m2(scales, group_axis=group_axis).scales
    raise ValueError(f"unknown scale constraint mode: {mode!r}")
