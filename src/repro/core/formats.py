"""Numeric formats for ZeroQuant-FP.

Implements the ExMy floating-point grids the paper uses (E4M3, E5M2 for FP8;
E2M1, E3M0 for FP4) plus INT4/INT8 integer grids, with round-to-nearest-even
quantization onto the exact representable value set.

Conventions (documented in DESIGN.md §2):
  * qtorch-style saturating grids: no inf/NaN codes, values clamp to the
    max-magnitude representable number (the paper used the qtorch package;
    footnote 3 of the paper).
  * subnormals are represented exactly — at 4 bits they carry a large
    fraction of the usable grid.
  * rounding is round-to-nearest, ties-to-even on the mantissa grid.

Everything here is pure jnp and jit-safe; these functions are also the
oracles for the Pallas kernels (kernels/ref.py re-exports them).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FloatFormat",
    "IntFormat",
    "FORMATS",
    "get_format",
    "quantize_to_grid",
    "fp_encode",
    "fp_decode",
    "value_grid",
    "pow2i",
    "pack_nibbles",
    "unpack_nibbles",
]


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """A saturating ExMy mini-float format (sign + exp_bits + man_bits)."""

    name: str
    exp_bits: int
    man_bits: int
    bias: int

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def min_exp(self) -> int:
        # exponent of the smallest *normal* number
        return 1 - self.bias

    @property
    def max_exp(self) -> int:
        # all-ones exponent is a normal value (saturating grid, no inf/nan)
        return (2**self.exp_bits - 1) - self.bias

    @property
    def max_value(self) -> float:
        # largest magnitude: max exponent, full mantissa
        return float(2.0 ** self.max_exp * (2.0 - 2.0 ** (-self.man_bits)))

    @property
    def min_subnormal(self) -> float:
        return float(2.0 ** (self.min_exp - self.man_bits))

    def quantize(self, x):
        """Round x (any float array) to the nearest representable value."""
        return quantize_to_grid(x, self)


@dataclasses.dataclass(frozen=True)
class IntFormat:
    """A b-bit integer grid. Symmetric uses [-2^(b-1)+1, 2^(b-1)-1]."""

    name: str
    bits: int
    symmetric: bool = True

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def qmin(self) -> int:
        if self.symmetric:
            return -(2 ** (self.bits - 1) - 1)
        return -(2 ** (self.bits - 1))

    @property
    def levels(self) -> int:
        return 2**self.bits - 1 if self.symmetric else 2**self.bits


# ---------------------------------------------------------------------------
# Registry. E3M0 with bias 3 gives magnitudes {0.25 .. 16} (pure powers of
# two) per the paper's FP4 alternative; E2M1 bias 1 gives the paper's grid
# {0, .5, 1, 1.5, 2, 3, 4, 6}.
# ---------------------------------------------------------------------------
FORMATS = {
    "fp8_e4m3": FloatFormat("fp8_e4m3", exp_bits=4, man_bits=3, bias=7),
    "fp8_e5m2": FloatFormat("fp8_e5m2", exp_bits=5, man_bits=2, bias=15),
    "fp4_e2m1": FloatFormat("fp4_e2m1", exp_bits=2, man_bits=1, bias=1),
    "fp4_e3m0": FloatFormat("fp4_e3m0", exp_bits=3, man_bits=0, bias=3),
    "fp16": FloatFormat("fp16", exp_bits=5, man_bits=10, bias=15),
    "bf16": FloatFormat("bf16", exp_bits=8, man_bits=7, bias=127),
    "int8": IntFormat("int8", bits=8, symmetric=True),
    "int8_asym": IntFormat("int8_asym", bits=8, symmetric=False),
    "int4": IntFormat("int4", bits=4, symmetric=True),
    "int4_asym": IntFormat("int4_asym", bits=4, symmetric=False),
}


def get_format(name: str):
    if name in ("none", "fp32", None):
        return None
    return FORMATS[name]


# ---------------------------------------------------------------------------
# Exact powers of two.
# XLA CPU lowers exp2 to a polynomial approximation (exp2(13.0) == 8192.004!)
# which corrupts grid arithmetic. Build 2^k exactly from the IEEE-754 bit
# pattern instead: for integer k in [-126, 127], f32(2^k) = (k+127) << 23.
# (This is also the idiom the Pallas kernels use on TPU: a VPU integer op.)
# ---------------------------------------------------------------------------
def pow2i(k):
    """Exact 2**k for integer-valued k (array ok), clamped to f32 normals."""
    k = jnp.clip(jnp.asarray(k, jnp.int32), -126, 127)
    bits = (k + 127).astype(jnp.uint32) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


# ---------------------------------------------------------------------------
# Grid rounding
# ---------------------------------------------------------------------------
def quantize_to_grid(x, fmt: FloatFormat):
    """Round-to-nearest-even onto the saturating ExMy grid of ``fmt``.

    Works on any float dtype; computes in f32. The grid step at |x| in
    [2^e, 2^(e+1)) is 2^(e - man_bits); below the smallest normal the step
    is the subnormal step 2^(min_exp - man_bits). jnp.round implements
    ties-to-even, giving RNE on the mantissa.
    """
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    absx = jnp.abs(x)
    max_val = fmt.max_value

    # exponent of each element, clamped to the normal range
    # (for |x| < min normal we use min_exp => subnormal step)
    safe = jnp.maximum(absx, jnp.float32(1e-38))
    e = jnp.floor(jnp.log2(safe))
    e = jnp.clip(e, fmt.min_exp, fmt.max_exp)
    step = pow2i(e.astype(jnp.int32) - fmt.man_bits)
    q = jnp.round(x / step) * step
    # rounding can carry into the next binade (e.g. 1.96 -> 2.0); that value
    # is still on the grid, but it may exceed max_val at the top binade.
    q = jnp.clip(q, -max_val, max_val)
    q = jnp.where(absx == 0, jnp.zeros_like(q), q)
    return q.astype(orig_dtype)


@lru_cache(maxsize=None)
def value_grid(name: str) -> np.ndarray:
    """All representable values of a float format, sorted (numpy, cached)."""
    fmt = FORMATS[name]
    assert isinstance(fmt, FloatFormat)
    vals = [0.0]
    for e in range(fmt.min_exp, fmt.max_exp + 1):
        for m in range(2**fmt.man_bits):
            vals.append(2.0**e * (1.0 + m / 2**fmt.man_bits))
    # subnormals: exponent field 0 -> value = 2^min_exp * (m / 2^man_bits)
    for m in range(1, 2**fmt.man_bits):
        vals.append(2.0**fmt.min_exp * (m / 2**fmt.man_bits))
    vals = sorted(set(vals))
    return np.array([-v for v in reversed(vals) if v] + vals, dtype=np.float32)


# ---------------------------------------------------------------------------
# Code <-> value (used by the packed-weight serving path and Pallas kernels)
# Code layout: [sign | exp_bits | man_bits], most significant bit = sign.
# ---------------------------------------------------------------------------
def fp_encode(x, fmt: FloatFormat):
    """Encode floats to integer codes (uint8) of ``fmt``. x must already be
    on the grid (i.e. pass through quantize_to_grid first)."""
    x = x.astype(jnp.float32)
    sign = (x < 0) | ((x == 0) & (jnp.signbit(x)))
    absx = jnp.abs(x)
    safe = jnp.maximum(absx, fmt.min_subnormal)
    e = jnp.floor(jnp.log2(safe)).astype(jnp.int32)
    e = jnp.clip(e, fmt.min_exp, fmt.max_exp)
    is_subnormal = absx < 2.0**fmt.min_exp
    exp_field = jnp.where(is_subnormal, 0, e + fmt.bias)
    scale = pow2i(jnp.where(is_subnormal, fmt.min_exp, e))
    frac = absx / scale  # in [1, 2) normal; [0, 1) subnormal
    man = jnp.where(
        is_subnormal,
        jnp.round(frac * 2**fmt.man_bits),
        jnp.round((frac - 1.0) * 2**fmt.man_bits),
    ).astype(jnp.int32)
    # mantissa overflow from rounding (can't happen if x is on-grid, but be safe)
    carry = man >= 2**fmt.man_bits
    man = jnp.where(carry, 0, man)
    exp_field = jnp.where(carry, exp_field + 1, exp_field)
    exp_field = jnp.clip(exp_field, 0, 2**fmt.exp_bits - 1)
    code = (
        sign.astype(jnp.int32) << (fmt.exp_bits + fmt.man_bits)
        | (exp_field << fmt.man_bits)
        | man
    )
    return code.astype(jnp.uint8)


def fp_decode(code, fmt: FloatFormat):
    """Decode integer codes back to float32 values."""
    code = code.astype(jnp.int32)
    man_mask = 2**fmt.man_bits - 1
    exp_mask = 2**fmt.exp_bits - 1
    man = code & man_mask
    exp_field = (code >> fmt.man_bits) & exp_mask
    sign = (code >> (fmt.exp_bits + fmt.man_bits)) & 1
    is_subnormal = exp_field == 0
    e = jnp.where(is_subnormal, fmt.min_exp, exp_field - fmt.bias)
    frac = jnp.where(
        is_subnormal,
        man.astype(jnp.float32) / 2**fmt.man_bits,
        1.0 + man.astype(jnp.float32) / 2**fmt.man_bits,
    )
    val = pow2i(e) * frac
    return jnp.where(sign == 1, -val, val)


def pack_nibbles(codes):
    """Pack uint8 4-bit codes (last dim even) into half as many bytes.
    Low nibble = even index, high nibble = odd index."""
    lo = codes[..., 0::2].astype(jnp.uint8)
    hi = codes[..., 1::2].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed):
    """Inverse of pack_nibbles. Copy-free bitwise construction: a broadcasted
    shift against an appended [0, 4] axis replaces the old stack+reshape
    (an extra copy per decode). The iota keeps kernel bodies free of
    captured constant arrays — kernels.common re-exports this function for
    the in-VMEM decode of every Pallas kernel."""
    pair = packed.shape + (2,)
    shifts = jax.lax.broadcasted_iota(jnp.uint8, pair, len(pair) - 1) * 4
    nib = (packed[..., None] >> shifts) & jnp.uint8(0x0F)
    return nib.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
