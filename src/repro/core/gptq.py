"""GPTQ in JAX — Hessian-guided one-shot weight quantization (paper §3).

Faithful to Frantar et al. (GPTQ) as used by ZeroQuant-FP:
  * H = 2 * X X^T accumulated over a calibration stream (X: layer inputs),
  * dampened (lambda * mean(diag(H))) for stability,
  * columns quantized left-to-right in blocks; each column's rounding error
    is fed back into the not-yet-quantized columns via the inverse-Hessian
    Cholesky factor,
  * group-wise (FGQ) scales recomputed at each group boundary from the
    *current* (error-compensated) weights,
  * the rounding grid is pluggable: any format from core.formats (INT4/8,
    E2M1, E3M0, E4M3 ...), which is exactly the paper's INT-vs-FP axis,
  * optional power-of-2 scale constraints (M1/M2) applied to the group scale
    at the moment it is computed — constraining *during* GPTQ lets the error
    feedback absorb the snap error (slightly stronger than post-hoc snapping).

Everything is jit-compatible: the column loop is a lax.fori_loop over a
statically-shaped block, the block loop is a Python loop over a static count.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .formats import FloatFormat, get_format
from .quantize import QuantizedTensor, _grid_max, _round_to_fmt
from .scales import apply_scale_constraint

__all__ = ["HessianState", "hessian_init", "hessian_update", "gptq_quantize"]


class HessianState(NamedTuple):
    h: jnp.ndarray  # (in, in) running 2*X X^T
    n: jnp.ndarray  # scalar sample count


def hessian_init(in_features: int) -> HessianState:
    return HessianState(
        h=jnp.zeros((in_features, in_features), jnp.float32),
        n=jnp.zeros((), jnp.float32),
    )


@jax.jit
def hessian_update(state: HessianState, x) -> HessianState:
    """Accumulate calibration inputs. x: (..., in_features)."""
    x = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    m = x.shape[0]
    # running mean of 2 X^T X, numerically like GPTQ's streaming update
    h = state.h * (state.n / (state.n + m)) + (2.0 / (state.n + m)) * (x.T @ x)
    return HessianState(h=h, n=state.n + m)


def _invh_cholesky(h, damp: float):
    """Dampened inverse-Hessian upper Cholesky factor (GPTQ's Hinv)."""
    d = h.shape[0]
    mean_diag = jnp.mean(jnp.diag(h))
    h = h + (damp * mean_diag + 1e-8) * jnp.eye(d, dtype=h.dtype)
    # Hinv via Cholesky: H = L L^T ; GPTQ uses chol(inv(H), upper)
    hinv = jnp.linalg.inv(h)
    # symmetrize for numerical safety before the second Cholesky
    hinv = 0.5 * (hinv + hinv.T)
    l = jnp.linalg.cholesky(hinv)  # lower
    return l.T  # upper triangular factor U with Hinv = U^T U ... (GPTQ conv.)


def _group_scale(wblk, fmt, scale_mode: str, s_max=None):
    """Scale per output row from current block columns (one FGQ group).

    wblk: (out, group_size). Returns (out, 1).

    For M2 the compute group is the output *row* across its FGQ groups
    (paper: "a (multiple) row(s) of a matrix"), so S_max per row must be
    known before the sequential column sweep; we estimate it from the
    initial full-row absmax (error feedback perturbs weights only mildly,
    and the k>=0 clip makes any violation saturate safely at S_max).
    """
    absmax = jnp.max(jnp.abs(wblk), axis=-1, keepdims=True)
    s = jnp.maximum(absmax / _grid_max(fmt), 1e-12)
    if scale_mode == "m1":
        s = apply_scale_constraint(s, "m1")
    elif scale_mode == "m2":
        ratio = jnp.maximum(s_max / s, 1.0)
        k = jnp.clip(jnp.ceil(jnp.log2(ratio)), 0, 31)
        from .formats import pow2i
        s = s_max * pow2i(-k.astype(jnp.int32))
    return s


def gptq_quantize(
    w,
    hessian: jnp.ndarray,
    fmt_name: str,
    group_size: int = 256,
    scale_mode: str = "none",
    damp: float = 0.01,
    block: int = 128,
):
    """GPTQ-quantize a (out, in) weight given the input Hessian (in, in).

    Returns (w_hat, QuantizedTensor). ``w_hat`` is the dequantized result
    (what the layer should use); the QuantizedTensor carries the on-grid
    normalized values + the (possibly pow-2 constrained) scales for packing.
    """
    in_f = w.shape[1]
    if group_size <= 0 or group_size > in_f:
        group_size = in_f
    block = min(block, group_size)
    qvals, scales = _gptq_core(w, hessian, fmt_name, group_size, scale_mode, damp, block)
    qt = QuantizedTensor(
        values=qvals,
        scale=scales,
        zero_point=None,
        group_size=group_size,
        fmt_name=fmt_name,
    )
    return qt.dequantize(), qt


@partial(jax.jit, static_argnames=("fmt_name", "group_size", "scale_mode", "damp", "block"))
def _gptq_core(w, hessian, fmt_name, group_size, scale_mode, damp, block):
    fmt = get_format(fmt_name)
    out_f, in_f = w.shape
    assert in_f % group_size == 0
    assert group_size % block == 0
    n_groups = in_f // group_size

    w = w.astype(jnp.float32)
    hinv_u = _invh_cholesky(hessian.astype(jnp.float32), damp)

    # per-row S_max for M2 (see _group_scale)
    row_absmax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    s_max_row = jnp.maximum(row_absmax / _grid_max(fmt), 1e-12)

    def quant_col(col, s):
        q = _round_to_fmt(col[:, None] / s, fmt)[:, 0]
        return q

    def process_block(carry, b):
        """Quantize columns [b*block, (b+1)*block) with error feedback."""
        w_cur, qvals, scales = carry
        wblk = jax.lax.dynamic_slice(w_cur, (0, b * block), (out_f, block))
        ublk = jax.lax.dynamic_slice(hinv_u, (b * block, b * block), (block, block))

        # group boundary: block is aligned so a group spans whole blocks;
        # recompute the scale from the *current* error-fed weights when this
        # block starts a new group.
        g = (b * block) // group_size
        is_group_start = (b * block) % group_size == 0
        s_prev = jax.lax.dynamic_slice(scales, (0, g), (out_f, 1))
        s_new = _group_scale(
            jax.lax.dynamic_slice(w_cur, (0, g * group_size), (out_f, group_size)),
            fmt,
            scale_mode,
            s_max=s_max_row,
        )
        s = jnp.where(is_group_start, s_new, s_prev)
        scales = jax.lax.dynamic_update_slice(scales, s, (0, g))

        def col_step(i, val):
            wb, qb, errb = val
            col = wb[:, i]
            d = ublk[i, i]
            q = quant_col(col, s)
            err = (col - q * s[:, 0]) / d
            # feed error into remaining columns of this block
            row = ublk[i]  # (block,)
            mask = (jnp.arange(block) > i).astype(wb.dtype)
            wb = wb - jnp.outer(err, row * mask)
            qb = qb.at[:, i].set(q)
            errb = errb.at[:, i].set(err)
            return wb, qb, errb

        qblk0 = jnp.zeros((out_f, block), jnp.float32)
        errb0 = jnp.zeros((out_f, block), jnp.float32)
        wblk, qblk, errblk = jax.lax.fori_loop(0, block, col_step, (wblk, qblk0, errb0))

        qvals = jax.lax.dynamic_update_slice(qvals, qblk, (0, b * block))

        # propagate accumulated block error to all later columns:
        # W[:, later] -= Err_blk @ U[blk, later]
        u_later = jax.lax.dynamic_slice(hinv_u, (b * block, 0), (block, in_f))
        col_idx = jnp.arange(in_f)
        later_mask = (col_idx >= (b + 1) * block).astype(w_cur.dtype)
        w_cur = w_cur - (errblk @ (u_later * later_mask[None, :]))
        # keep the already-finalized columns of this block intact in w_cur
        w_cur = jax.lax.dynamic_update_slice(w_cur, qblk * s, (0, b * block))
        return (w_cur, qvals, scales), None

    qvals0 = jnp.zeros((out_f, in_f), jnp.float32)
    scales0 = jnp.ones((out_f, n_groups), jnp.float32)
    carry = (w, qvals0, scales0)
    n_blocks = in_f // block
    (w_final, qvals, scales), _ = jax.lax.scan(
        process_block, carry, jnp.arange(n_blocks)
    )
    return qvals, scales
