"""SSE streaming demo: two concurrent clients sharing a prompt prefix.

    PYTHONPATH=src python examples/sse_stream_demo.py [--port 8000]
    PYTHONPATH=src python examples/sse_stream_demo.py --sampled

Boots a pocket-size W4A8-packed engine behind the asyncio front-end
(untrained weights — this demo is about the transport, not the
tokens; pass ``--trained`` for the cached benchmark checkpoint),
exposes the OpenAI-style ``POST /v1/completions`` endpoint, then plays
*client* against its own server: two requests whose prompts share a
24-token system prefix are POSTed concurrently with ``stream: true``
and their SSE token chunks are printed as they interleave. Because
both prompts hash to the same scale-frozen prefix pages, the second
request maps them straight from the content-addressed prefix cache —
the demo prints the engine's ``prefix_hit_tokens`` to prove it.

``--sampled`` sends per-request ``temperature/top_k/top_p/seed`` so the
two streams draw from the in-graph sampler instead of greedy argmax
(seeded: rerunning the demo reproduces the same tokens).

Everything is stdlib asyncio — the same raw-socket SSE parsing works
against any host running ``repro.runtime.frontend.serve_http``.
"""
import argparse
import asyncio
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from repro import models
from repro.core.policy import QuantPolicy
from repro.core.ptq import quantize_tree
from repro.models.config import ArchConfig
from repro.runtime.frontend import AsyncServer, serve_http
from repro.runtime.serve import (CachePolicy, SchedulerConfig, Server,
                                 ServerConfig)


async def stream_completion(host, port, name, payload):
    """POST /v1/completions with stream:true, print chunks as they land,
    return the token list. Pure stdlib: reads SSE lines off the socket."""
    import time

    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(payload).encode()
    t_post = time.perf_counter()
    writer.write(b"POST /v1/completions HTTP/1.1\r\nHost: demo\r\n"
                 b"Content-Type: application/json\r\n"
                 + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    toks, finish, ttft = [], None, None
    while True:
        line = (await reader.readline()).decode().rstrip("\r\n")
        if line == "data: [DONE]":
            break
        if not line.startswith("data: "):
            continue  # headers / keep-alive blanks
        choice = json.loads(line[6:])["choices"][0]
        if choice["finish_reason"] is not None:
            finish = choice["finish_reason"]
        elif choice.get("token") is not None:
            if ttft is None:
                ttft = time.perf_counter() - t_post
            toks.append(choice["token"])
            print(f"  [{name}] token #{choice['index_in_stream']}: "
                  f"{choice['token']}")
    writer.close()
    await writer.wait_closed()
    ttft_ms = f"{ttft * 1e3:.1f} ms" if ttft is not None else "n/a"
    print(f"  [{name}] done ({finish}), time-to-first-token {ttft_ms}: "
          f"{toks}")
    return toks


def _build_engine(trained):
    """A W4A8-packed engine: pocket config + random init by default
    (seconds to boot), or the cached opt-mini benchmark checkpoint."""
    import jax

    if trained:
        from benchmarks.common import BENCH_CFG as cfg
        from benchmarks.common import trained_params
        params = trained_params()
    else:
        cfg = ArchConfig(
            name="sse-demo", family="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
            attn_kind="gqa", norm_kind="layernorm", act_kind="relu",
            mlp_gated=False, use_bias=True, pos_embedding="learned",
            tie_embeddings=True, max_position=256, attn_chunk=128)
        params = models.init_params(cfg, jax.random.PRNGKey(0))
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3",
                         scale_mode="m2", lorc_rank=8)
    packed = quantize_tree(params, models.build_def(cfg), policy)
    # mixed-precision cache policy: FP8 active pages, and the shared system
    # prefix both clients ride is transcoded to packed FP4 when it freezes
    cache = CachePolicy(active_fmt="fp8_e4m3", frozen_fmt="fp4_e2m1")
    return cfg, Server(packed, cfg,
                       ServerConfig(slots=2, max_seq=96, page_size=8,
                                    cache=cache,
                                    scheduler=SchedulerConfig()))


async def run_demo(args):
    cfg, engine = _build_engine(args.trained)
    front = AsyncServer(engine)
    srv = await serve_http(front, host=args.host, port=args.port)
    port = srv.sockets[0].getsockname()[1]
    print(f"serving /v1/completions on {args.host}:{port}")

    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, size=24).tolist()
    prompts = {"alice": shared + [7, 7, 3], "bob": shared + [40]}
    print(f"two clients share a {len(shared)}-token prompt prefix; "
          f"tails {prompts['alice'][-3:]} vs {prompts['bob'][-1:]}")

    def payload(name, seed):
        p = {"prompt": prompts[name], "max_tokens": args.max_new,
             "stream": True}
        if args.sampled:
            p.update(temperature=0.8, top_k=20, top_p=0.95, seed=seed)
        return p

    try:
        await asyncio.gather(
            stream_completion(args.host, port, "alice", payload("alice", 1)),
            stream_completion(args.host, port, "bob", payload("bob", 2)))
    finally:
        srv.close()
        await srv.wait_closed()
        await front.close()

    hits = engine.stats["prefix_hit_tokens"]
    print(f"prefix cache served {hits} of the second prompt's tokens from "
          f"shared pages ({engine.prefix_hit_rate():.1%} hit rate) — "
          f"concurrent requests batched in one engine, one prefill "
          f"of the shared prefix")
    assert hits > 0, "expected the shared prefix to hit the page cache"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = pick a free port")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--sampled", action="store_true",
                    help="seeded in-graph sampling instead of greedy")
    ap.add_argument("--trained", action="store_true",
                    help="serve the cached opt-mini benchmark checkpoint "
                         "instead of untrained pocket weights (trains "
                         "BENCH_TRAIN_STEPS steps on first use)")
    args = ap.parse_args()
    asyncio.run(run_demo(args))


if __name__ == "__main__":
    main()
