"""Batched W4A8 serving with the packed deployment checkpoint.

    PYTHONPATH=src python examples/serve_w4a8.py [--backend pallas_interpret]

Loads (or trains) the benchmark model, packs it to the W4A8 deployment form
(FP4-E2M1 nibbles + M2 pow-2 scales + LoRC factors), then serves a stream of
batched requests through the continuous-batching engine. ``--backend
pallas_interpret`` executes every quantized matmul through the Pallas TPU
kernel in interpret mode (slow on CPU; bit-identical quantization).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from repro import models
from repro.core.policy import QuantPolicy
from repro.core.ptq import quantize_tree
from repro.kernels import ops
from repro.runtime.serve import Request, Server

from benchmarks.common import BENCH_CFG, trained_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "pallas", "pallas_interpret"])
    ap.add_argument("--kv-fmt", default="fp8_e4m3", choices=["fp8_e4m3", "bf16"],
                    help="KV page payload: packed FP8 codes with "
                         "per-(page, head) M2 scales, or bf16 (fallback)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--scheduler", default="token_budget",
                    choices=["reserve", "token_budget"],
                    help="admission policy: reserve-on-admit (worst-case "
                         "pages up front) or token-budget (prompt pages + "
                         "headroom, on-demand growth, page-steal preemption)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-new-tail", type=int, default=0,
                    help="long-tail workload: every third request gets this "
                         "max_new instead of --max-new (0 = uniform). "
                         "Reproduces the serving benchmark's long-tail mix")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page-pool capacity (0 = fully backed slots); set "
                         "it tight to watch the token-budget scheduler "
                         "preempt by page steal")
    args = ap.parse_args()

    params = trained_params()
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", scale_mode="m2",
                        lorc_rank=8)
    packed = quantize_tree(params, models.build_def(BENCH_CFG), policy)

    # deployment footprint
    import jax

    dense_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    packed_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(packed))
    print(f"checkpoint: {dense_bytes/2**20:.1f} MiB dense -> "
          f"{packed_bytes/2**20:.1f} MiB packed W4A8 "
          f"({dense_bytes/packed_bytes:.2f}x smaller)")

    rng = np.random.default_rng(0)
    # 'pallas' routes every PackedLinear matmul through the fused single-pass
    # W4A8 kernel (compiled on TPU, interpreter elsewhere)
    kv_fmt = None if args.kv_fmt == "bf16" else args.kv_fmt
    server = Server(packed, BENCH_CFG, slots=args.slots, max_seq=96,
                    kernel_backend=args.backend, kv_fmt=kv_fmt, page_size=32,
                    scheduler=args.scheduler,
                    pool_pages=args.pool_pages or None)
    print(f"kv cache: paged {args.kv_fmt}, "
          f"{server.kv_bytes_per_token():.0f} B/token "
          f"(bf16 baseline {server.kv_bf16_bytes_per_token():.0f} B/token); "
          f"scheduler={args.scheduler}")
    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(1, BENCH_CFG.vocab_size, size=rng.integers(3, 10)).tolist()
        max_new = args.max_new
        if args.max_new_tail and rid % 3 == 0:
            max_new = args.max_new_tail
        r = Request(rid=rid, prompt=prompt, max_new=max_new)
        reqs.append(r)
        server.submit(r)

    t0 = time.time()
    steps = 0
    while server.step():
        steps += 1
        if steps > 2000:
            break
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in {dt:.1f}s "
          f"({steps} engine steps, backend={args.backend})")
    print(f"slot utilization {server.utilization():.3f}, "
          f"{server.stats['preemptions']} preemptions / "
          f"{server.stats['resumes']} resumes "
          f"({server.stats['pages_stolen']} pages stolen)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.prompt} -> {r.out}")
    ops.set_backend("ref")


if __name__ == "__main__":
    main()
