"""Batched W4A8 serving with the packed deployment checkpoint.

    PYTHONPATH=src python examples/serve_w4a8.py [--backend pallas_interpret]

Loads (or trains) the benchmark model, packs it to the W4A8 deployment form
(FP4-E2M1 nibbles + M2 pow-2 scales + LoRC factors), then serves a stream of
batched requests through the continuous-batching engine. ``--backend
pallas_interpret`` executes every quantized matmul through the Pallas TPU
kernel in interpret mode (slow on CPU; bit-identical quantization).

``--families`` additionally serves the whisper-tiny enc-dec config (write-
once cross-attention pages) and the minicpm3 MLA config (latent decode
kernel) through the same paged FP8 engine, asserting each request's greedy
tokens are identical to the legacy contiguous-cache decode path.

``--shared-prefix N`` prepends an N-token shared system prompt to every
request: after the first request freezes its full prompt pages, every
later request maps them straight from the content-addressed prefix cache
(refcount++, zero prefill compute) and streams only its own tail. Compare
against ``--no-prefix-cache`` to see the cold-engine cost.

``--frozen-kv-fmt fp4_e2m1`` (with ``--shared-prefix``) switches the
cache policy to mixed precision: shared pages are transcoded FP8 ->
packed FP4 E2M1 exactly once at the moment the prefix cache freezes
them, roughly halving the bytes-per-token of prefix residency. The
drain prints live page counts per format and the density ratio.

``--temperature T`` (with ``--top-k/--top-p/--seed``) switches every
request from greedy argmax to in-graph seeded sampling — same compiled
decode step, per-row fixed-trace masks, reproducible run-to-run.

``--inject-faults SEED`` serves the same workload through a seeded
deterministic fault schedule (a NaN-poisoned decode row, a bit-flipped
host spill, a transient allocator stall): exactly the poisoned requests
end ``status='failed'``, the tampered spill is caught by its CRC and
re-prefilled, and everything else finishes untouched. ``--audit-every N``
runs the pool-ownership auditor every N decode steps; the drain always
ends with an audit, so a broken pool invariant fails loudly.

``--mesh N`` serves through a ``(data=1, model=N)`` device mesh (simulated
host devices on CPU): KV pages, their scales and the decode attention are
sharded by head across the N model shards while the host scheduler stays
a single brain. Greedy tokens are identical to ``--mesh 1`` and the drain
prints per-shard page residency next to the per-format residency stats.
"""
import argparse
import os
import sys
import time
from collections import Counter

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# --mesh N shards the engine over N simulated host devices; the XLA flag
# must be set before the backend initializes, and the repro imports below
# pull in jax — so pre-scan argv here, ahead of argparse
if any(a == "--mesh" or a.startswith("--mesh=") for a in sys.argv[1:]):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import numpy as np

from repro import models
from repro.core.policy import QuantPolicy
from repro.core.ptq import quantize_tree
from repro.kernels import ops
from repro.runtime.serve import (CachePolicy, FaultPlan, MeshPlan, Request,
                                 SamplingParams, SchedulerConfig, Server,
                                 ServerConfig)

from benchmarks.common import BENCH_CFG, trained_params


def _train_smoke(cfg, tag, steps=150, with_frames=False):
    """Briefly train a smoke config (cached in .bench_cache) so greedy
    logit gaps are decisive and fp8-vs-legacy token identity is meaningful."""
    import jax
    import jax.numpy as jnp

    from repro.checkpoint.manager import latest_step, restore, save
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.steps import TrainState, make_train_step
    from repro.optimizer import AdamWConfig, adamw_init

    from benchmarks.common import CACHE

    ckpt = os.path.join(CACHE, f"{tag}_{steps}")
    init = models.init_params(cfg, jax.random.PRNGKey(0))
    if latest_step(ckpt) is not None:
        return restore(ckpt, init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=24, global_batch=8,
                    seed=7)
    oc = AdamWConfig(lr=6e-3, warmup=20, total_steps=steps)
    state = TrainState(params=init, opt=adamw_init(init, oc))
    step_fn = jax.jit(make_train_step(cfg, oc), donate_argnums=(0,))
    data = SyntheticLM(dc)
    frng = np.random.default_rng(11)
    for step in range(steps):
        b = dict(data.batch(step))
        if with_frames:
            b["frames"] = jnp.asarray(frng.normal(
                size=(dc.global_batch, cfg.encoder_seq, cfg.d_model)
            ).astype(np.float32))
        state, _ = step_fn(state, b)
    save(ckpt, steps, state.params)
    return state.params


def _greedy_legacy(params, cfg, prompt, max_new, max_seq, frames=None):
    import jax.numpy as jnp

    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    if frames is not None:
        batch["frames"] = jnp.asarray(frames[None])
    logits, caches = models.prefill(params, cfg, batch, max_seq)
    out = [int(jnp.argmax(logits[0]))]
    idx = len(prompt)
    while len(out) < max_new:
        logits, caches = models.decode_step(
            params, cfg, jnp.asarray([[out[-1]]], jnp.int32), caches, idx)
        out.append(int(jnp.argmax(logits[0])))
        idx += 1
    return out


def serve_families(backend):
    """Whisper-tiny (enc-dec cross pages) and minicpm3 (MLA latent decode)
    through the paged FP8 engine, token-identical to the legacy decode."""
    from repro.configs import get_smoke

    rng = np.random.default_rng(0)
    for arch, tag in (("whisper-tiny", "whisper_smoke"),
                      ("minicpm3-4b", "mla_smoke")):
        cfg = get_smoke(arch)
        encdec = cfg.encoder_layers > 0
        params = _train_smoke(cfg, tag, with_frames=encdec)
        srv = Server(params, cfg,
                     ServerConfig(slots=3, max_seq=64,
                                  cache=CachePolicy(active_fmt="fp8_e4m3"),
                                  page_size=8, kernel_backend=backend,
                                  a_fmt=None))
        reqs = []
        for rid in range(3):
            prompt = rng.integers(1, cfg.vocab_size,
                                  size=rng.integers(3, 10)).tolist()
            frames = (rng.normal(size=(cfg.encoder_seq, cfg.d_model))
                      .astype(np.float32) if encdec else None)
            r = Request(rid=rid, prompt=prompt, max_new=6, frames=frames)
            reqs.append(r)
            srv.submit(r)
        srv.run_until_drained()
        for r in reqs:
            ref = _greedy_legacy(params, cfg, r.prompt, 6, 64, r.frames)
            assert r.out == ref, (arch, r.rid, r.out, ref)
        extra = (f", cross pages for {cfg.encoder_seq} encoder frames"
                 if encdec else ", latent decode kernel path")
        print(f"{arch}: {len(reqs)} requests through the paged FP8 engine"
              f"{extra}; greedy tokens identical to the legacy decode")
        for r in reqs[:2]:
            print(f"  req {r.rid}: {r.prompt} -> {r.out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "pallas", "pallas_interpret"])
    ap.add_argument("--kv-fmt", default="fp8_e4m3", choices=["fp8_e4m3", "bf16"],
                    help="active KV page payload: packed FP8 codes with "
                         "per-(page, head) M2 scales, or bf16 (fallback)")
    ap.add_argument("--frozen-kv-fmt", default="none",
                    choices=["none", "fp4_e2m1"],
                    help="frozen (prefix-cache-registered) page payload: "
                         "'fp4_e2m1' transcodes each shared page FP8 -> "
                         "packed FP4 exactly once at the freeze point "
                         "(needs --shared-prefix and FP8 --kv-fmt); 'none' "
                         "keeps frozen pages in the active format")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--scheduler", default="token_budget",
                    choices=["reserve", "token_budget"],
                    help="admission policy: reserve-on-admit (worst-case "
                         "pages up front) or token-budget (prompt pages + "
                         "headroom, on-demand growth, page-steal preemption)")
    ap.add_argument("--engine", default="mixed",
                    choices=["mixed", "alternating"],
                    help="engine step shape: 'mixed' piggybacks one "
                         "request's next prefill chunk onto every decode "
                         "step (one fused program, decode rows never "
                         "stall); 'alternating' runs dedicated prefill "
                         "and decode programs (the legacy baseline)")
    ap.add_argument("--prefill-token-budget", type=int, default=0,
                    help="max prompt tokens piggybacked per mixed step "
                         "(rounded down to a page multiple; 0 = the "
                         "prefill-chunk default). Smaller = smoother "
                         "decode latency, larger = faster prompt "
                         "ingestion")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax, the "
                         "default; > 0 samples in-graph with the "
                         "fixed-trace top-k/top-p masks)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k most likely tokens before "
                         "sampling (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: keep the smallest probability "
                         "mass >= p (1.0 = disabled)")
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request RNG seed base; request rid uses "
                         "seed+rid so streams differ but each is "
                         "reproducible run-to-run")
    ap.add_argument("--max-new-tail", type=int, default=0,
                    help="long-tail workload: every third request gets this "
                         "max_new instead of --max-new (0 = uniform). "
                         "Reproduces the serving benchmark's long-tail mix")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page-pool capacity (0 = fully backed slots); set "
                         "it tight to watch the token-budget scheduler "
                         "preempt by page steal")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a shared system prompt of this many "
                         "tokens to every request — full scale-frozen "
                         "pages of it are served from the content-"
                         "addressed prefix cache (refcounted, zero "
                         "prefill compute) after the first request")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the shared-prefix page cache (cold "
                         "baseline for --shared-prefix)")
    ap.add_argument("--families", action="store_true",
                    help="also serve the whisper-tiny enc-dec and minicpm3 "
                         "MLA smoke configs through the paged FP8 engine "
                         "(asserts token identity vs the legacy decode)")
    ap.add_argument("--inject-faults", type=int, default=0, metavar="SEED",
                    help="draw a seeded FaultPlan (NaN decode row, "
                         "corrupted spill, transient allocator stall) and "
                         "serve through it: exactly the poisoned requests "
                         "fail, everyone else is unaffected (0 = off)")
    ap.add_argument("--audit-every", type=int, default=0, metavar="N",
                    help="run the pool-ownership auditor every N decode "
                         "steps (raises PoolCorruptionError with a state "
                         "dump on any broken invariant; 0 = off)")
    ap.add_argument("--mesh", type=int, default=1, metavar="N",
                    help="shard the engine over a (1, N) device mesh: KV "
                         "pages + decode attention split by head across N "
                         "model-axis shards (simulated host devices on "
                         "CPU); greedy tokens stay identical to --mesh 1 "
                         "and the drain prints per-shard page residency")
    args = ap.parse_args()

    if args.families:
        serve_families(None if args.backend == "ref" else args.backend)
        return

    params = trained_params()
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", scale_mode="m2",
                        lorc_rank=8)
    packed = quantize_tree(params, models.build_def(BENCH_CFG), policy)

    # deployment footprint
    import jax

    dense_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    packed_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(packed))
    print(f"checkpoint: {dense_bytes/2**20:.1f} MiB dense -> "
          f"{packed_bytes/2**20:.1f} MiB packed W4A8 "
          f"({dense_bytes/packed_bytes:.2f}x smaller)")

    rng = np.random.default_rng(0)
    # 'pallas' routes every PackedLinear matmul through the fused single-pass
    # W4A8 kernel (compiled on TPU, interpreter elsewhere)
    kv_fmt = None if args.kv_fmt == "bf16" else args.kv_fmt
    frozen_fmt = None if args.frozen_kv_fmt == "none" else args.frozen_kv_fmt
    if frozen_fmt and not args.shared_prefix:
        ap.error("--frozen-kv-fmt needs --shared-prefix: frozen FP4 pages "
                 "only ever hold prefix-cache-registered pages")
    cache = CachePolicy(active_fmt=kv_fmt, frozen_fmt=frozen_fmt)
    page_size = 16 if args.shared_prefix else 32
    plan = None
    if args.inject_faults:
        # draw faults inside the first half of the workload's decode-step
        # span so they land while every slot is still busy
        n_tail = (args.requests + 2) // 3 if args.max_new_tail else 0
        total = (n_tail * args.max_new_tail
                 + (args.requests - n_tail) * args.max_new)
        span = max(4, total // max(1, args.slots) // 2)
        plan = FaultPlan.seeded(args.inject_faults, slots=args.slots,
                                max_step=span)
        print(f"fault schedule (seed {args.inject_faults}): "
              f"NaN rows at {plan.nan_logits}, corrupt spill ordinals "
              f"{plan.corrupt_spills}, allocator blanked on ticks "
              f"{plan.alloc_fail_ticks}")
    mesh_plan = MeshPlan(data=1, model=args.mesh) if args.mesh > 1 else None
    server = Server(packed, BENCH_CFG,
                    ServerConfig(slots=args.slots, max_seq=96,
                                 kernel_backend=args.backend, cache=cache,
                                 page_size=page_size,
                                 pool_pages=args.pool_pages or None,
                                 prefix_cache=not args.no_prefix_cache,
                                 strict=False, audit_every=args.audit_every,
                                 scheduler=SchedulerConfig(
                                     policy=args.scheduler,
                                     engine=args.engine,
                                     prefill_token_budget=(
                                         args.prefill_token_budget or None)),
                                 mesh=mesh_plan),
                    faults=plan)
    frozen_note = (f" + frozen {args.frozen_kv_fmt}" if frozen_fmt else "")
    mesh_note = (f"; mesh=(1, {args.mesh}) — KV heads split over "
                 f"{args.mesh} model shards" if mesh_plan else "")
    print(f"kv cache: paged {args.kv_fmt}{frozen_note}, "
          f"{server.kv_bytes_per_token():.0f} B/token "
          f"(bf16 baseline {server.kv_bf16_bytes_per_token():.0f} B/token); "
          f"scheduler={args.scheduler}{mesh_note}")
    shared = (rng.integers(1, BENCH_CFG.vocab_size,
                           size=args.shared_prefix).tolist()
              if args.shared_prefix else [])
    if args.temperature > 0:
        print(f"sampling: temperature={args.temperature}, "
              f"top_k={args.top_k}, top_p={args.top_p}, "
              f"seed base {args.seed} (+rid per request)")
    reqs = []
    for rid in range(args.requests):
        prompt = shared + rng.integers(1, BENCH_CFG.vocab_size,
                                       size=rng.integers(3, 10)).tolist()
        max_new = args.max_new
        if args.max_new_tail and rid % 3 == 0:
            max_new = args.max_new_tail
        sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed + rid)
        r = Request(rid=rid, prompt=prompt, max_new=max_new, sampling=sp)
        reqs.append(r)
        server.submit(r)

    t0 = time.time()
    steps = 0
    while True:
        went = server.step()
        steps += 1
        if steps > 2000:
            break
        if not went:
            if server.queue or server.preempted:
                continue  # deferred admission (e.g. injected alloc stall)
            break
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    by_status = Counter(r.status for r in reqs)
    status = ", ".join(f"{n} {s}" for s, n in sorted(by_status.items()))
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in {dt:.1f}s "
          f"({steps} engine steps, backend={args.backend}; {status})")
    st = server.stats
    n_steps = max(st["steps"], 1)
    print(f"engine={server.engine}: {st['prefill_tokens']} prefill + "
          f"{st['decoded_tokens']} decode tokens across {st['programs']} "
          f"jitted programs; per-step mix "
          f"{st['prefill_tokens'] / n_steps:.1f} prefill / "
          f"{st['decoded_tokens'] / n_steps:.1f} decode tokens, "
          f"engine utilization {server.engine_utilization():.3f}")
    print(f"slot utilization {server.utilization():.3f}, "
          f"{server.stats['preemptions']} preemptions / "
          f"{server.stats['resumes']} resumes "
          f"({server.stats['pages_stolen']} pages stolen), "
          f"{server.stats['truncated']} truncated at max_seq")
    print(f"prefix cache: {server.stats['prefix_hit_tokens']} prompt tokens "
          f"served from shared pages ({server.prefix_hit_rate():.1%} hit "
          f"rate, {server.stats['prefix_hit_pages']} page hits, "
          f"{server.stats['prefix_reclaims']} reclaims)")
    if plan is not None:
        hit_rids = sorted(rid for (_, _, rid) in plan.nan_hits)
        failed = sorted(r.rid for r in reqs if r.status == "failed")
        print(f"fault injection landed: NaN rows hit requests {hit_rids}, "
              f"spills tampered for rids "
              f"{sorted(plan.corrupted_rids + plan.dropped_rids)} "
              f"({server.stats['spill_integrity_failures']} caught by CRC), "
              f"allocator blanked on ticks {plan.blocked_ticks}")
        assert failed == hit_rids, (failed, hit_rids)
        for r in reqs:
            if r.status == "failed":
                print(f"  req {r.rid} quarantined: {r.error}")
    summary = server.audit()  # raises PoolCorruptionError if anything broke
    print(f"pool audit clean at drain: {summary['pages_mapped']} mapped / "
          f"{summary['pages_free']} free / {summary['pages_parked']} parked "
          f"pages, {summary['slabs_free']} slabs free")
    resid = server.cache_residency()
    print(f"page residency: {resid['n_active_live']} live "
          f"{args.kv_fmt} pages ({resid['active_bytes_per_token']:.0f} "
          f"B/token) + {resid['n_frozen_live']} live frozen pages "
          f"({resid['frozen_bytes_per_token']:.0f} B/token)")
    if frozen_fmt:
        ratio = (resid["frozen_bytes_per_token"]
                 / resid["active_bytes_per_token"])
        print(f"  {server.stats['fp4_frozen_pages']} pages transcoded "
              f"FP8 -> packed FP4 at freeze; frozen/active page density "
              f"{ratio:.2f}x")
    if mesh_plan is not None:
        per = server.shard_residency()
        detail = ", ".join(f"{dev}: {b / 2**10:.1f} KiB"
                           for dev, b in per.items())
        print(f"per-shard page residency ({len(per)} devices): {detail}")
    for r in reqs[:3]:
        tag = " [truncated]" if r.truncated else ""
        print(f"  req {r.rid}: {r.prompt} -> {r.out}{tag}")
    ops.set_backend("ref")


if __name__ == "__main__":
    main()
