"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps,
then reproduce the paper's full PTQ matrix on it (Table 2 + Table 3 shape).

    PYTHONPATH=src python examples/train_and_quantize.py --preset small
    PYTHONPATH=src python examples/train_and_quantize.py --preset paper

``paper`` trains the ~100M opt-125m-class config for 300 steps (hours on
CPU, minutes on accelerators); ``small`` (default) runs the same pipeline at
benchmark scale in a few minutes. Results print as a Table-2-shaped grid.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from repro.configs import get_config
from repro.core.policy import QuantPolicy
from repro.core.ptq import gptq_quantize_lm
from repro.data.pipeline import DataConfig
from repro.optimizer import AdamWConfig
from repro.runtime.train import TrainLoopConfig, train_loop

from benchmarks.common import BENCH_CFG, eval_ppl
from benchmarks import common


MATRIX = [
    ("W16A16", None, None),
    ("W8A8  INT-INT", QuantPolicy(w_fmt="int8", a_fmt="int8", method="gptq"), "int8"),
    ("W8A8  FP-FP ", QuantPolicy(w_fmt="fp8_e4m3", a_fmt="fp8_e4m3", method="gptq"), "fp8_e4m3"),
    ("W4A8  INT-INT", QuantPolicy(w_fmt="int4", a_fmt="int8", method="gptq"), "int8"),
    ("W4A8  FP-FP ", QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq"), "fp8_e4m3"),
    ("W4A8L FP-FP ", QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq",
                                 lorc_rank=8), "fp8_e4m3"),
    ("W4A8L FP-FP M2", QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq",
                                   lorc_rank=8, scale_mode="m2"), "fp8_e4m3"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["small", "paper"], default="small")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    if args.preset == "paper":
        # ~100M params: opt-125m config at seq 512
        cfg = get_config("opt-125m")
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=512, global_batch=8)
        seq = 512
    else:
        cfg = BENCH_CFG
        dc = common.data_cfg()
        seq = common.SEQ

    n_params = sum(
        int(jax.numpy.size(x)) for x in jax.tree.leaves(
            jax.eval_shape(lambda: __import__("repro").models.init_params(
                cfg, jax.random.PRNGKey(0))))
    ) if False else cfg.param_count()
    print(f"== training {cfg.name} (~{n_params/1e6:.0f}M params) for {args.steps} steps ==")
    oc = AdamWConfig(lr=3e-3 if args.preset == "small" else 6e-4,
                     warmup=20, total_steps=args.steps)
    lc = TrainLoopConfig(steps=args.steps, log_every=25,
                         ckpt_dir=f".ckpt_{cfg.name}", ckpt_every=100)
    state, hist = train_loop(
        cfg, dc, oc, lc,
        on_metrics=lambda m: print(f"  step {m['step']:4d} nll {m['nll']:.3f} "
                                   f"({m['sec']:.2f}s/step)"),
    )

    print("\n== PTQ matrix (GPTQ, group 256; LoRC rank 8; M2 pow-2 scales) ==")
    from repro.data.pipeline import SyntheticLM

    calib_src = SyntheticLM(dataclasses.replace(dc, seed=99))
    calib = [{"tokens": calib_src.batch(i)["tokens"]} for i in range(8)]
    print(f"{'scheme':16s} {'ppl':>9s} {'delta':>8s}")
    base = None
    for label, policy, a_fmt in MATRIX:
        if policy is None:
            p = state.params
        else:
            p = gptq_quantize_lm(state.params, cfg, calib, policy)
        ppl = eval_ppl(p, cfg=cfg, a_fmt=a_fmt)
        base = base or ppl
        print(f"{label:16s} {ppl:9.3f} {(ppl / base - 1) * 100:+7.2f}%")


if __name__ == "__main__":
    main()
