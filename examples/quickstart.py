"""Quickstart: the paper's pipeline end-to-end on a pocket-size model.

    PYTHONPATH=src python examples/quickstart.py

1. train a tiny OPT-style LM on the synthetic corpus (~1 min on CPU),
2. PTQ it with the paper's headline scheme
   (W4 FP4-E2M1 / A8 FP8-E4M3, GPTQ group-256, LoRC rank 8, M2 scales),
3. compare perplexity FP16 vs W4A8,
4. pack to the deployment form and decode a few tokens with the serving
   engine (the packed path exercises the Pallas-kernel semantics).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from repro import models
from repro.core.policy import QuantPolicy
from repro.core.ptq import gptq_quantize_lm, quantize_tree
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optimizer import AdamWConfig
from repro.runtime.serve import CachePolicy, Request, Server, ServerConfig
from repro.runtime.train import TrainLoopConfig, train_loop

from benchmarks.common import BENCH_CFG, calib_batches, data_cfg, eval_ppl


def main():
    print("== 1. train ==")
    steps = int(os.environ.get("QUICKSTART_STEPS", "200"))
    oc = AdamWConfig(lr=3e-3, warmup=20, total_steps=steps)
    state, hist = train_loop(
        BENCH_CFG, data_cfg(), oc, TrainLoopConfig(steps=steps, log_every=50),
        on_metrics=lambda m: print(f"  step {m['step']:4d} nll {m['nll']:.3f}"),
    )
    params = state.params

    print("== 2. PTQ (GPTQ + LoRC + M2 scales, W4A8 FP-FP) ==")
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq",
                        scale_mode="m2", lorc_rank=8)
    qparams = gptq_quantize_lm(params, BENCH_CFG, calib_batches(4), policy,
                               progress=True)

    print("== 3. perplexity ==")
    ppl_fp16 = eval_ppl(params)
    ppl_w4a8 = eval_ppl(qparams, a_fmt="fp8_e4m3")
    print(f"  W16A16: {ppl_fp16:.3f}   W4A8(FP-FP+LoRC+M2): {ppl_w4a8:.3f} "
          f"(+{(ppl_w4a8 / ppl_fp16 - 1) * 100:.1f}%)")

    print("== 4. pack + serve ==")
    packed = quantize_tree(params, models.build_def(BENCH_CFG), policy)
    server = Server(packed, BENCH_CFG,
                    ServerConfig(slots=2, max_seq=64,
                                 cache=CachePolicy(active_fmt="fp8_e4m3")))
    server.submit(Request(rid=0, prompt=[5, 17, 99, 3], max_new=8))
    server.submit(Request(rid=1, prompt=[1, 2, 3], max_new=8))
    reqs = [server.queue[0], server.queue[1]]
    for _ in range(20):
        if not server.step():
            break
    for r in reqs:
        print(f"  request {r.rid}: prompt {r.prompt} -> generated {r.out}")
    print("done.")


if __name__ == "__main__":
    main()
